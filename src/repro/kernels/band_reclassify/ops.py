"""Public wrappers: align band windows to tile boundaries and clamp them."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.band_reclassify.kernel import (
    band_reclassify as _kernel,
    multiview_band_reclassify as _mv_kernel,
)
from repro.kernels.band_reclassify.ref import band_reclassify_ref  # noqa: F401


def multiview_band_reclassify(F, labels, W, b, start_rows, end_rows, *,
                              cap: int = 4096, block_n: int = 512,
                              interpret: bool = False,
                              with_overflow: bool = False):
    """Relabel rows [start_rows[v], end_rows[v]) of the shared scratch
    table under each view's model (W[v], b[v]) in ONE kernel launch.

    labels: (k, n) int8, rows aligned to F's row order. Windows are
    tile-aligned and capacity-clamped per view: a view whose aligned window
    end_rows[v] − aligned_start[v] exceeds `cap` is silently truncated, so
    rows past the capacity keep STALE labels. `with_overflow=True`
    additionally returns the per-view (k,) bool truncation flag so the
    SKIING driver can trigger reorganization instead of shipping those
    stale labels (the sharded multi-view update step does exactly that)."""
    n, d = F.shape
    start_rows = jnp.asarray(start_rows, jnp.int32)
    end_rows = jnp.asarray(end_rows, jnp.int32)
    start_blocks = jnp.clip(start_rows // block_n, 0,
                            max(0, (n - cap) // block_n))
    requested = end_rows - start_blocks * block_n
    widths = jnp.clip(requested, 0, cap)
    out = _mv_kernel(F, labels, W, jnp.asarray(b, jnp.float32),
                     start_blocks, widths, cap=cap, block_n=block_n,
                     interpret=interpret)
    if with_overflow:
        return out, requested > cap
    return out


def band_reclassify(F_sorted, labels, w, b, start_row, end_row, *,
                    cap: int = 4096, block_n: int = 512,
                    interpret: bool = False):
    """Relabel rows [start_row, end_row) of the eps-sorted table under (w,b).

    labels: (n,) int8. The window is tile-aligned and capacity-clamped; the
    caller (SKIING driver) must ensure end_row − aligned_start ≤ cap."""
    n, d = F_sorted.shape
    start_row = jnp.asarray(start_row, jnp.int32)
    end_row = jnp.asarray(end_row, jnp.int32)
    start_block = jnp.clip(start_row // block_n, 0,
                           max(0, (n - cap) // block_n))
    width = jnp.clip(end_row - start_block * block_n, 0, cap)
    out = _kernel(F_sorted, labels[:, None], w, jnp.asarray(b, jnp.float32),
                  start_block, width, cap=cap, block_n=block_n,
                  interpret=interpret)
    return out[:, 0]
