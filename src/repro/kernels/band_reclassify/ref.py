"""Pure-jnp oracle for band_reclassify (dynamic-slice formulation)."""
import jax
import jax.numpy as jnp


def multiview_band_reclassify_ref(F, labels, W, b, start_blocks, widths, *,
                                  cap: int, block_n: int):
    """Multi-view oracle: the single-view dynamic-slice formulation applied
    per view against the one shared table."""
    k, n = labels.shape

    def one(lab_v, w_v, b_v, sb_v, width_v):
        return band_reclassify_ref(F, lab_v[:, None], w_v, b_v, sb_v, width_v,
                                   cap=cap, block_n=block_n)[:, 0]

    return jax.vmap(one)(labels, W, b, start_blocks, widths)


def band_reclassify_ref(F_sorted, labels, w, b, start_block, width, *,
                        cap: int, block_n: int):
    n, d = F_sorted.shape
    start = start_block * block_n
    Fb = jax.lax.dynamic_slice(F_sorted, (start, 0), (cap, d))
    eps = jnp.einsum("nd,d->n", Fb.astype(jnp.float32), w.astype(jnp.float32)) - b
    new = jnp.where(eps >= 0, 1, -1).astype(jnp.int8)[:, None]
    old = jax.lax.dynamic_slice(labels, (start, 0), (cap, 1))
    in_band = (jnp.arange(cap) < width)[:, None]
    merged = jnp.where(in_band, new, old)
    return jax.lax.dynamic_update_slice(labels, merged, (start, 0))
