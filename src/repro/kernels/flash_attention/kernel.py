"""Causal GQA flash-attention forward (Pallas TPU).

Grid (b, h, q_blocks, kv_blocks), kv innermost → sequential online-softmax
accumulation in VMEM scratch (m, l, acc). Causality is *block-skipped*:
kv blocks strictly above the diagonal never touch VMEM or the MXU, so
FLOPs/bytes ≈ N²/2, matching the roofline accounting used in §Perf.
GQA is handled in the k/v index maps (kv head = q head // group) — no
repeated-KV materialization anywhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, block_q: int, block_k: int):
    iq, ik = pl.program_id(2), pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(ik <= iq)  # causal block skip
    def _accum():
        q = q_ref[0, 0].astype(jnp.float32)         # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)         # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)         # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        # causal mask — only the diagonal block needs it
        rows = jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = (rows + iq * block_q) >= (cols + ik * block_k)
        s = jnp.where(jnp.logical_or(ik < iq, mask), s, NEG_INF)

        m_prev = m_ref[...]                          # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + p @ v
        m_ref[...] = m_new

    @pl.when(ik == iq)  # last contributing block for this q block
    def _write():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, block_q: int = 256, block_k: int = 256,
                    interpret: bool = False):
    """q: (b, nq, s, hd); k/v: (b, nkv, s, hd); causal. Returns (b, nq, s, hd)."""
    b, nq, s, hd = q.shape
    nkv = k.shape[1]
    assert nq % nkv == 0
    group = nq // nkv
    assert s % block_q == 0 and s % block_k == 0
    scale = hd ** -0.5
    grid = (b, nq, s // block_q, s // block_k)

    kernel = functools.partial(_flash_kernel, scale=scale, block_q=block_q,
                               block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nq, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
