"""Pure-jnp oracle: causal GQA attention."""
import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v):
    b, nq, s, hd = q.shape
    nkv = k.shape[1]
    group = nq // nkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * hd ** -0.5
    mask = jnp.tril(jnp.ones((s, s), bool))
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32)).astype(q.dtype)
