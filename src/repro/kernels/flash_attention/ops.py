"""Public wrapper for the flash-attention kernel (layout + padding)."""
from __future__ import annotations

from repro.kernels.flash_attention.kernel import flash_attention as _kernel


def flash_attention(q, k, v, *, block_q: int = 256, block_k: int = 256,
                    interpret: bool = False):
    """q: (b, s, nq, hd) [model layout]; k/v: (b, s, nkv, hd). Causal."""
    s = q.shape[1]
    bq = min(block_q, s)
    bk = min(block_k, s)
    q_t = q.transpose(0, 2, 1, 3)
    k_t = k.transpose(0, 2, 1, 3)
    v_t = v.transpose(0, 2, 1, 3)
    out = _kernel(q_t, k_t, v_t, block_q=bq, block_k=bk, interpret=interpret)
    return out.transpose(0, 2, 1, 3)
