"""Pure-jnp oracle for the WKV6 kernel: the exact sequential recurrence."""
import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, la, u):
    """r/k/v/la: (b, H, s, K); u: (H, K). Exact per-token recurrence."""
    b, H, s, K = r.shape

    def step(S, inp):
        rr, kk, vv, ll = inp                     # (b, H, K)
        wkv = S + jnp.einsum("bhk,bhv->bhkv", u[None] * kk, vv)
        o = jnp.einsum("bhk,bhkv->bhv", rr, wkv)
        S = S * jnp.exp(ll)[..., None] + jnp.einsum("bhk,bhv->bhkv", kk, vv)
        return S, o

    xs = tuple(t.transpose(2, 0, 1, 3) for t in (r, k, v, la))
    S0 = jnp.zeros((b, H, K, K), jnp.float32)
    _, outs = jax.lax.scan(step, S0, xs)
    return outs.transpose(1, 2, 0, 3)            # (b, H, s, K)
