"""WKV6 (RWKV-6 "Finch") chunked recurrence as a Pallas TPU kernel.

Grid (b, H, n_chunks) with chunks innermost: the per-head state S ∈ R^{K×V}
lives in VMEM scratch across the sequential chunk dimension — the HBM
traffic is exactly one read of r/k/v/decay and one write of the output per
token (the recurrence state never round-trips to HBM, which is what makes
the attention-free family memory-optimal on TPU).

Math (identical to models/rwkv6.wkv_chunked, the deployed training path):
    a       = cumsum(log-decay) within the chunk           (<= 0)
    o_inter = (r ⊙ exp(a_prev)) · S_in
    o_intra = tril_strict[(r ⊙ exp(a_prev))(k ⊙ exp(-a))ᵀ] · v   (clipped exp)
    o_bonus = (r ⊙ u ⊙ k summed over K) · v
    S_out   = diag(exp(a_last)) S_in + (k ⊙ exp(a_last − a))ᵀ v
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_CLIP = 40.0


def _wkv_kernel(r_ref, k_ref, v_ref, la_ref, u_ref, o_ref, s_ref, *,
                chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    rr = r_ref[0, 0, 0].astype(jnp.float32)          # (c, K)
    kk = k_ref[0, 0, 0].astype(jnp.float32)
    vv = v_ref[0, 0, 0].astype(jnp.float32)
    ll = la_ref[0, 0, 0].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)            # (1, K)

    a = jnp.cumsum(ll, axis=0)                    # (c, K), <= 0, decreasing
    a_prev = a - ll
    S = s_ref[...]                                # (K, V)

    o_inter = (rr * jnp.exp(a_prev)) @ S
    r_f = rr * jnp.exp(jnp.clip(a_prev, -_CLIP, _CLIP))
    k_f = kk * jnp.exp(jnp.clip(-a, -_CLIP, _CLIP))
    att = jax.lax.dot_general(r_f, k_f, (((1,), (1,)), ((), ())))  # (c, c)
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    att = jnp.where(rows > cols, att, 0.0)        # strictly lower triangular
    o_intra = att @ vv
    o_bonus = jnp.sum(rr * u * kk, axis=1, keepdims=True) * vv

    o_ref[0, 0, 0] = (o_inter + o_intra + o_bonus).astype(o_ref.dtype)

    a_last = a[-1:]
    k_dec = kk * jnp.exp(a_last - a)
    s_ref[...] = S * jnp.exp(a_last).T + jax.lax.dot_general(
        k_dec, vv, (((0,), (0,)), ((), ())))


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, la, u, *, chunk: int = 64, interpret: bool = False):
    """r/k/v/la: (b, H, s, K) [s % chunk == 0]; u: (H, K).

    Returns out (b, H, s, K) f32."""
    b, H, s, K = r.shape
    assert s % chunk == 0
    n = s // chunk
    grid = (b, H, n)
    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    rs = lambda t: t.reshape(b, H, n, chunk, K)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, K), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, K), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, K), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, K), lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, K), lambda bi, hi, ci: (hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, chunk, K),
                               lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, H, n, chunk, K), jnp.float32),
        scratch_shapes=[pltpu.VMEM((K, K), jnp.float32)],
        interpret=interpret,
    )(rs(r), rs(k), rs(v), rs(la), u)
    return out.reshape(b, H, s, K)
