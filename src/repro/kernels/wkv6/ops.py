"""Public wrapper: model layout (b, s, H, K) in/out, seq padding."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.wkv6.kernel import wkv6 as _kernel


def wkv6(r, k, v, la, u, *, chunk: int = 64, interpret: bool = False):
    """r/k/v/la: (b, s, H, K); u: (H, K). Returns (b, s, H, K) f32.

    The recurrence runs in f32 regardless of input dtype (the decay cumsum
    compounds bf16 rounding over the sequence — same policy as the model's
    wkv_chunked path)."""
    b, s, H, K = r.shape
    c = min(chunk, s)
    pad = (-s) % c
    tr = lambda t: jnp.pad(t.astype(jnp.float32),
                           ((0, 0), (0, pad), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    out = _kernel(tr(r), tr(k), tr(v), tr(la), u, chunk=c, interpret=interpret)
    return out.transpose(0, 2, 1, 3)[:, :s]
