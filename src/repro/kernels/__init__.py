"""Pallas TPU kernels for the hot loops.

Each kernel is a subpackage with:
  kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (padding, GQA reshapes, interpret flag)
  ref.py    — pure-jnp oracle used by the allclose test sweeps

Kernels:
  eps_affine       — eps = F·w − b fused with labeling + per-tile positive
                     counts (paper's full-relabel / reorg eps pass)
  band_reclassify  — incremental step: stream only the water-band tiles
                     HBM→VMEM and relabel in place (paper's core saving)
  flash_attention  — causal GQA flash attention forward (backbone hot spot)
  decode_attention — single-token GQA attention over a long KV cache
  wkv6             — RWKV-6 chunked WKV recurrence (state resident in VMEM)
"""
