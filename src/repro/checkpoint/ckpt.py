"""Sharded checkpointing with atomic commits and resharding restore.

Layout:  <dir>/step_<n>.tmp/  -> fsync'd leaves + manifest.json -> rename to
<dir>/step_<n>/ (atomic commit: a crash mid-write never corrupts the latest
complete checkpoint — the fault-tolerance contract the train loop relies on).

Restore takes an *abstract* state (ShapeDtypeStructs with shardings) and
`device_put`s each leaf with its target sharding — so a checkpoint written
on one mesh restores onto any other mesh (elastic scaling path).

At real multi-host scale each host writes only its addressable shards; this
single-process container writes full arrays but keeps the same manifest
format (`shard_id` field) so the layout is forward-compatible.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Optional

import jax
import numpy as np

_BF16 = "bfloat16"


def _leafname(i: int) -> str:
    return f"leaf_{i:05d}.npy"


def save_checkpoint(directory: str, state, step: int) -> str:
    """Synchronous atomic save. Returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat, treedef = jax.tree_util.tree_flatten_with_path(state)
    manifest = {"step": step, "shard_id": 0, "leaves": []}
    for i, (path, leaf) in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        dtype = str(leaf.dtype)
        if dtype == _BF16:
            arr = arr.view(np.uint16)
        np.save(os.path.join(tmp, _leafname(i)), arr)
        manifest["leaves"].append({
            "path": jax.tree_util.keystr(path),
            "dtype": dtype,
            "shape": list(np.shape(arr)),
            "file": _leafname(i),
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, abstract_state, step: Optional[int] = None):
    """Restore onto the shardings carried by `abstract_state` (reshards as
    needed — the elastic-scaling path)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_state)
    by_path = {l["path"]: l for l in manifest["leaves"]}
    leaves = []
    for kpath, ab in flat:
        key = jax.tree_util.keystr(kpath)
        meta = by_path[key]
        arr = np.load(os.path.join(path, meta["file"]))
        if meta["dtype"] == _BF16:
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        sharding = getattr(ab, "sharding", None)
        if sharding is not None:
            leaves.append(jax.device_put(arr, sharding))
        else:
            leaves.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["step"]


class AsyncCheckpointer:
    """Background-thread checkpointing: the train loop hands off host copies
    and keeps stepping while the previous save commits (compute/IO overlap)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue(maxsize=1)
        self._err: Optional[BaseException] = None
        self._t = threading.Thread(target=self._worker, daemon=True)
        self._t.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            state_host, step = item
            try:
                save_checkpoint(self.directory, state_host, step)
                self._gc()
            except BaseException as e:  # surfaced on next save()/close()
                self._err = e
            finally:
                self._q.task_done()

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def save(self, state, step: int):
        if self._err:
            raise self._err
        # snapshot to host memory before releasing the device buffers
        host = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)), state)
        self._q.put((host, step))  # blocks only if a save is already queued

    def wait(self):
        self._q.join()
        if self._err:
            raise self._err

    def close(self):
        self._q.put(None)
        self._t.join(timeout=60)
        if self._err:
            raise self._err
