"""Exact assigned config for pixtral-12b (see registry for provenance)."""
from repro.configs.registry import get_config, smoke_config

CONFIG = get_config("pixtral-12b")
SMOKE = smoke_config("pixtral-12b")
