"""Assigned-architecture registry: exact configs + reduced smoke twins.

Sources are cited per the assignment table ([hf:...] / [arXiv:...]).
`head_pad_to` pads q-heads in-step to a multiple of the 16-way model axis
(math-exact zero padding, see models/layers.py) for archs whose head count
does not divide 16.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ModelConfig, SHAPES

ARCHS: Dict[str, ModelConfig] = {}


def _register(cfg: ModelConfig) -> ModelConfig:
    ARCHS[cfg.name] = cfg
    return cfg


_register(ModelConfig(
    name="granite-3-2b", family="dense", num_layers=40, d_model=2048,
    num_heads=32, num_kv_heads=8, head_dim=64, d_ff=8192, vocab_size=49155,
    source="hf:ibm-granite/granite-3.0-2b-base",
))

_register(ModelConfig(
    name="tinyllama-1.1b", family="dense", num_layers=22, d_model=2048,
    num_heads=32, num_kv_heads=4, head_dim=64, d_ff=5632, vocab_size=32000,
    source="arXiv:2401.02385",
))

_register(ModelConfig(
    name="qwen3-14b", family="dense", num_layers=40, d_model=5120, microbatches=2,
    num_heads=40, num_kv_heads=8, head_dim=128, d_ff=17408, vocab_size=151936,
    qk_norm=True, rope_theta=1e6, head_pad_to=48,
    source="hf:Qwen/Qwen3-14B",
))

_register(ModelConfig(
    name="qwen1.5-32b", family="dense", num_layers=64, d_model=5120, microbatches=4,
    num_heads=40, num_kv_heads=40, head_dim=128, d_ff=27392, vocab_size=152064,
    qkv_bias=True, rope_theta=1e6, head_pad_to=48,
    # MHA x 64 layers: the 32k cache is >21 GiB/chip in bf16 — f8 KV (§Perf H3)
    kv_cache_dtype="float8_e4m3fn",
    source="hf:Qwen/Qwen1.5-32B",
))

_register(ModelConfig(
    name="llama4-scout-17b-a16e", family="moe", num_layers=48, d_model=5120, microbatches=2,
    num_heads=40, num_kv_heads=8, head_dim=128, d_ff=8192, vocab_size=202048,
    num_experts=16, num_experts_per_tok=1, num_shared_experts=1,
    head_pad_to=48, rope_theta=5e5,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
))

_register(ModelConfig(
    name="dbrx-132b", family="moe", num_layers=40, d_model=6144, microbatches=4,
    num_heads=48, num_kv_heads=8, head_dim=128, d_ff=10752, vocab_size=100352,
    num_experts=16, num_experts_per_tok=4, rope_theta=5e5,
    source="hf:databricks/dbrx-base",
))

_register(ModelConfig(
    name="rwkv6-3b", family="ssm", num_layers=32, d_model=2560,
    num_heads=40, num_kv_heads=40, head_dim=64, d_ff=8960, vocab_size=65536,
    rwkv_head_size=64, head_pad_to=48,
    source="arXiv:2404.05892",
))

_register(ModelConfig(
    name="whisper-tiny", family="audio", num_layers=4, num_encoder_layers=4,
    d_model=384, num_heads=6, num_kv_heads=6, head_dim=64, d_ff=1536,
    vocab_size=51865, encoder_seq_len=1500, head_pad_to=16,
    source="arXiv:2212.04356",
))

_register(ModelConfig(
    name="pixtral-12b", family="vlm", num_layers=40, d_model=5120, microbatches=2,
    num_heads=32, num_kv_heads=8, head_dim=128, d_ff=14336, vocab_size=131072,
    num_image_tokens=1024, rope_theta=1e6,
    source="hf:mistralai/Pixtral-12B-2409",
))

_register(ModelConfig(
    name="jamba-v0.1-52b", family="hybrid", num_layers=32, d_model=4096, microbatches=8,
    num_heads=32, num_kv_heads=8, head_dim=128, d_ff=14336, vocab_size=65536,
    num_experts=16, num_experts_per_tok=2, moe_every=2, moe_offset=1,
    attn_every=8, attn_offset=3,
    source="arXiv:2403.19887",
))


def get_config(name: str) -> ModelConfig:
    return ARCHS[name]


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family twin for CPU smoke tests."""
    full = ARCHS[name]
    common = dict(
        name=full.name + "-smoke", num_layers=4, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256, head_pad_to=0,
        remat_policy="none", microbatches=1,
    )
    if full.family == "hybrid":
        common.update(num_layers=8, attn_every=4, attn_offset=1,
                      num_experts=4, num_experts_per_tok=2, moe_every=2, moe_offset=1)
    elif full.family == "moe":
        common.update(num_experts=4,
                      num_experts_per_tok=min(2, full.num_experts_per_tok),
                      num_shared_experts=full.num_shared_experts)
    elif full.family == "ssm":
        common.update(rwkv_head_size=16, num_heads=4, num_kv_heads=4)
    elif full.family == "audio":
        common.update(num_layers=2, num_encoder_layers=2, encoder_seq_len=16,
                      num_kv_heads=4)
    elif full.family == "vlm":
        common.update(num_image_tokens=8)
    return dataclasses.replace(full, **common)


# which shape cells run for which arch (per spec: skip long_500k for pure
# full-attention archs; note the skip in DESIGN.md)
LONG_CTX_ARCHS = ("rwkv6-3b", "jamba-v0.1-52b")


def cells():
    """All runnable (arch, shape) dry-run cells."""
    out = []
    for name in ARCHS:
        for sname in SHAPES:
            if sname == "long_500k" and name not in LONG_CTX_ARCHS:
                continue
            out.append((name, sname))
    return out
