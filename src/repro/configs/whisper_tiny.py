"""Exact assigned config for whisper-tiny (see registry for provenance)."""
from repro.configs.registry import get_config, smoke_config

CONFIG = get_config("whisper-tiny")
SMOKE = smoke_config("whisper-tiny")
