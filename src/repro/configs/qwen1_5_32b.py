"""Exact assigned config for qwen1.5-32b (see registry for provenance)."""
from repro.configs.registry import get_config, smoke_config

CONFIG = get_config("qwen1.5-32b")
SMOKE = smoke_config("qwen1.5-32b")
