from repro.configs.base import HazyConfig, ModelConfig, ShapeConfig, SHAPES, SMOKE_SHAPES
from repro.configs.registry import ARCHS, cells, get_config, smoke_config, LONG_CTX_ARCHS
