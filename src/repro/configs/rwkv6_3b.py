"""Exact assigned config for rwkv6-3b (see registry for provenance)."""
from repro.configs.registry import get_config, smoke_config

CONFIG = get_config("rwkv6-3b")
SMOKE = smoke_config("rwkv6-3b")
