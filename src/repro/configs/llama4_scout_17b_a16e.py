"""Exact assigned config for llama4-scout-17b-a16e (see registry for provenance)."""
from repro.configs.registry import get_config, smoke_config

CONFIG = get_config("llama4-scout-17b-a16e")
SMOKE = smoke_config("llama4-scout-17b-a16e")
