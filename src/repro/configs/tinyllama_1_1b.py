"""Exact assigned config for tinyllama-1.1b (see registry for provenance)."""
from repro.configs.registry import get_config, smoke_config

CONFIG = get_config("tinyllama-1.1b")
SMOKE = smoke_config("tinyllama-1.1b")
