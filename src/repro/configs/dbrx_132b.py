"""Exact assigned config for dbrx-132b (see registry for provenance)."""
from repro.configs.registry import get_config, smoke_config

CONFIG = get_config("dbrx-132b")
SMOKE = smoke_config("dbrx-132b")
