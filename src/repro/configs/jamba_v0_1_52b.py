"""Exact assigned config for jamba-v0.1-52b (see registry for provenance)."""
from repro.configs.registry import get_config, smoke_config

CONFIG = get_config("jamba-v0.1-52b")
SMOKE = smoke_config("jamba-v0.1-52b")
