"""Exact assigned config for granite-3-2b (see registry for provenance)."""
from repro.configs.registry import get_config, smoke_config

CONFIG = get_config("granite-3-2b")
SMOKE = smoke_config("granite-3-2b")
