"""Config system for hazy-jax.

Every assigned architecture is a `ModelConfig`; the paper's own workload (the
classification view) is a `HazyConfig`. Configs are plain frozen dataclasses so
they can be constructed without touching jax device state.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """A decoder-only / enc-dec transformer-family backbone.

    Field semantics follow the assignment table; `family` selects the block
    assembly in models/transformer.py.
    """

    name: str = "unnamed"
    family: str = "dense"  # dense | moe | ssm (rwkv6) | hybrid (jamba) | audio | vlm

    # Core dims
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 1024
    vocab_size: int = 1024

    # Attention details
    qk_norm: bool = False           # qwen3
    qkv_bias: bool = False          # qwen1.5
    rope_theta: float = 10_000.0
    attn_logit_softcap: float = 0.0

    # MoE (family == moe, or hybrid MoE layers)
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0     # llama4-scout has 1 shared expert
    moe_capacity_factor: float = 1.25
    moe_every: int = 1              # MoE on layers where (i % moe_every == moe_offset)
    moe_offset: int = 0

    # RWKV6 (family == ssm)
    rwkv_head_size: int = 64

    # Mamba (family == hybrid; jamba interleave)
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_dt_rank: int = 0          # 0 => ceil(d_model / 16)
    attn_every: int = 8             # attention at layers where i % attn_every == attn_offset
    attn_offset: int = 3

    # Enc-dec (family == audio / whisper)
    num_encoder_layers: int = 0
    encoder_seq_len: int = 1500     # whisper frame count (stub frontend)

    # VLM (family == vlm / pixtral)
    num_image_tokens: int = 0       # stub patch embeddings prepended to the text

    # Numerics / training
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    kv_cache_dtype: str = ""        # "" = dtype; "float8_e4m3fn" halves KV HBM
    norm_eps: float = 1e-5
    remat_policy: str = "full"      # none | dots | full (full fits v5e HBM; see §Perf)
    microbatches: int = 1           # gradient-accumulation steps per train step
    # Analysis-only: replace inner lax.scans (ssm chunks, loss chunks) with
    # python loops so cost_analysis counts every iteration (XLA counts while
    # bodies exactly once — see launch/analysis.py).
    unroll_inner_scans: bool = False
    scan_layers: bool = True

    # Sharding knobs
    head_pad_to: int = 0            # pad q (and MHA kv) heads to this count in-step; 0 = no pad
    mha_kv_padding: bool = True     # §Perf H3: shard MHA kv by padded heads
    logical_rules: str = "tp"       # tp | fsdp (small archs)

    # Notes for DESIGN.md / provenance
    source: str = ""

    @property
    def num_q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def padded_heads(self) -> int:
        return self.head_pad_to if self.head_pad_to else self.num_heads

    @property
    def cache_dtype(self) -> str:
        return self.kv_cache_dtype or self.dtype

    @property
    def mha_padded(self) -> bool:
        """MHA archs pad kv heads alongside q: attention is then fully
        head-sharded with zero kv gathers (§Perf H3)."""
        return (self.mha_kv_padding and bool(self.head_pad_to)
                and self.num_kv_heads == self.num_heads)

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.mamba_dt_rank if self.mamba_dt_rank else -(-self.d_model // 16)

    @property
    def rwkv_num_heads(self) -> int:
        return self.d_model // self.rwkv_head_size

    def padded_vocab(self, multiple: int = 512) -> int:
        return -(-self.vocab_size // multiple) * multiple

    def is_moe_layer(self, i: int) -> bool:
        if self.num_experts == 0:
            return False
        return i % self.moe_every == self.moe_offset

    def is_attn_layer(self, i: int) -> bool:
        """For hybrid (jamba): which layers are attention (rest are mamba)."""
        if self.family != "hybrid":
            return True
        return i % self.attn_every == self.attn_offset


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment table."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# Reduced shapes for smoke tests (same kinds, CPU-sized).
SMOKE_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 64, 2, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 64, 2, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 64, 2, "decode"),
    "long_500k": ShapeConfig("long_500k", 128, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class HazyConfig:
    """The paper's classification-view workload (core contribution)."""

    name: str = "hazy_view"
    num_entities: int = 1 << 16
    feature_dim: int = 256
    # Hölder conjugates (p, q); (inf, 1) for l1-normalized text (paper §3.2).
    holder_p: float = float("inf")
    holder_q: float = 1.0
    alpha: float = 1.0              # SKIING alpha (paper uses 1.0 everywhere)
    policy: str = "eager"           # eager | lazy | hybrid
    method: str = "svm"             # svm | logistic | ridge
    learning_rate: float = 0.1
    l2_reg: float = 1e-4
    buffer_frac: float = 0.01       # hybrid buffer = 1% of entities (paper §4.2)
    band_capacity_frac: float = 1 / 64  # jit-path static band capacity
    dtype: str = "float32"
    feature_dtype: str = "bfloat16"
