"""Exact assigned config for qwen3-14b (see registry for provenance)."""
from repro.configs.registry import get_config, smoke_config

CONFIG = get_config("qwen3-14b")
SMOKE = smoke_config("qwen3-14b")
