"""Per-view freshness runtime state (the scheduler's ledger).

Every `ViewDef` carries one `ViewRuntime`. The fields here — the inbox of
committed-but-unapplied batches, the suspension flag, the staleness and
last-refresh stamps — are the scheduler's OWN state: the FRS001 analysis
rule pins every mutation of them to this package, so refresh semantics
cannot fork across modules (`repro.analysis.freshness`).

A `Batch` is one WAL commit's worth of training rows as ONE engine round:
`(ids, labels, features)`. `features` is None for batches delivered to a
root view (the engine reads the base table's rows) and a pinned
`(len(ids), d)` matrix for batches a parent view emitted to a derived
view — the input features are computed ONCE, at emission time, from the
parent's post-batch model, so a derived view trains on the same feature
values no matter how late its refresh runs. That pinning is what makes
the lagged cascade bit-identical to an immediate one at the same commit
boundaries.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.obs.cost import ViewCostRecorder


@dataclasses.dataclass
class Batch:
    ids: List[int]
    labels: List[float]
    features: Optional[np.ndarray] = None   # pinned inputs (derived views)

    def __len__(self) -> int:
        return len(self.ids)


class ViewRuntime:
    """Freshness state of one view. Mutated ONLY inside `repro.scheduler`
    (enforced by FRS001); everyone else reads."""

    __slots__ = ("suspended", "inbox", "stale_since", "last_refresh_at",
                 "refreshes", "batches_applied", "rows_applied", "version",
                 "upstream_version_seen", "cost")

    def __init__(self, upstream_version_seen: int = -1):
        self.suspended = False
        self.inbox: List[Batch] = []        # committed, not yet applied
        self.stale_since: Optional[float] = None   # earliest unserved commit
        self.last_refresh_at: Optional[float] = None
        self.refreshes = 0
        self.batches_applied = 0
        self.rows_applied = 0
        # bumped whenever this view's labels/margins may have changed
        # (a consumed batch or a feature refresh) — consumers compare it
        # against `upstream_version_seen` to skip no-op feature pulls
        self.version = 0
        self.upstream_version_seen = upstream_version_seen
        # measured wall-clock refresh cost, recorded ALONGSIDE the modeled
        # SKIING charge the scheduler actually uses — never scheduling
        self.cost = ViewCostRecorder(1)

    def inbox_rows(self) -> int:
        return sum(len(b) for b in self.inbox)

    def staleness(self, now: float) -> float:
        return 0.0 if self.stale_since is None else max(0.0, now - self.stale_since)
