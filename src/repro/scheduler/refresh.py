"""Refresh mechanics: commit delivery, inbox consumption, feature pulls.

This module is the ONLY place freshness state (`ViewRuntime`) is mutated
and the only consumer of the catalog's DAG accessors (`topo_order`,
`children_of`, `parents_of`) — the FRS001 analysis rule keeps it that
way. Every function here runs under the executor's exclusive epoch gate:
either the calling statement already holds it (WAL flush, ALTER VIEW) or
the scheduler daemon takes it for the slice (`FreshnessScheduler.tick`).

Delivery protocol (what makes lagged == immediate at the same commit
boundaries):

  * a WAL commit delivers the group to each ROOT view of the table, in
    catalog order. An *immediate* view (no effective lag, not suspended)
    consumes the batch right there — exactly the pre-scheduler behavior;
    a *scheduled* view queues it in its inbox, preserving batch
    boundaries, so a later refresh replays the identical engine rounds.
  * when a view consumes a batch it emits an enriched batch to each
    consumer view: the SAME (ids, labels), plus input features pinned at
    emission time — the parent's post-batch margins over the batch's own
    pinned inputs. SGD is per-example sequential, so a derived view that
    trains on those pinned features reaches the same model whether it
    refreshes now or seconds later.
  * a refresh drains ancestors first (in topological order), consumes the
    inbox batch-by-batch, and — for derived views — re-pulls the full
    feature table from the parent's current margins (`refresh_features`,
    skipped when the parent's version hasn't moved).

`target_lag = 'downstream'` resolves through the catalog
(`Catalog.effective_lag`): the minimum of the consumers' effective lags;
unresolvable (no consumer declares a numeric lag) means the view is
maintained on demand only — i.e. it behaves as immediate.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.obs import clock
from repro.rdbms.ast_nodes import SqlError
from repro.scheduler.state import Batch


def is_scheduled(catalog, vd) -> bool:
    """Scheduler-managed: suspended, or declares a resolvable lag."""
    return vd.runtime.suspended or catalog.effective_lag(vd.name) is not None


def upstream_blocked(catalog, vd) -> bool:
    """True when a suspended ancestor is holding back committed data —
    refreshing `vd` could not make it fresh w.r.t. the base table."""
    for parent in catalog.parents_of(vd.name):
        if parent.runtime.suspended and (
                parent.runtime.inbox or parent.runtime.stale_since is not None):
            return True
        if upstream_blocked(catalog, parent):
            return True
    return False


# ---------------------------------------------------------------------------
# commit delivery (called from the WAL flush, commit lock + gate held)
# ---------------------------------------------------------------------------

def deliver_group(catalog, table: str, group) -> None:
    """Deliver one committed WAL group to the table's view DAG. Root views
    are fed in catalog order (immediate views consume synchronously, so
    behavior without lags is byte-identical to the pre-scheduler feed);
    every scheduled view in the subtree is stamped stale NOW — staleness
    is measured against the base-table commit, not against whenever an
    upstream view got around to emitting."""
    roots = [vd for vd in catalog.views_on(table) if vd.source is None]
    if not roots:
        return
    now = catalog.clock()
    for vd in catalog.subtree_of(roots):
        if is_scheduled(catalog, vd) and vd.runtime.stale_since is None:
            vd.runtime.stale_since = now

    pending: List = []

    def feed(batch_records):
        if not batch_records:
            return
        ids = [r.entity_id for r in batch_records]
        ys = [r.label for r in batch_records]
        for vd in roots:
            _offer(catalog, vd, Batch(list(ids), list(ys)), now)

    for rec in group:
        if rec.op == "delete":
            feed(pending)
            pending = []
            blocked = [v.name for v in catalog.subtree_of(roots)
                       if v.source is not None or is_scheduled(catalog, v)]
            if blocked:
                raise SqlError(
                    f"DELETE on {table!r} requires every view on it to be "
                    f"immediate (footnote-2 retrain cannot replay through "
                    f"inboxes/derived views); offending: {sorted(blocked)}")
            for vd in roots:
                vd.facade.delete_examples(rec.entity_id)
        else:
            pending.append(rec)
    feed(pending)


def _offer(catalog, vd, batch: Batch, now: float) -> None:
    """One committed batch arrives at `vd`: queue it (scheduled) or
    consume it on the spot (immediate)."""
    if is_scheduled(catalog, vd):
        vd.runtime.inbox.append(batch)
        if vd.runtime.stale_since is None:
            vd.runtime.stale_since = now
        return
    _consume_batch(catalog, vd, batch, now)


def _consume_batch(catalog, vd, batch: Batch, now: float) -> None:
    """Apply ONE batch as one engine round, then emit the enriched batch
    (features pinned from the post-batch model) to each consumer."""
    if batch.features is not None:
        vd.facade.insert_examples(batch.ids, batch.labels,
                                  features=batch.features)
    else:
        vd.facade.insert_examples(batch.ids, batch.labels)
    vd.runtime.batches_applied += 1
    vd.runtime.rows_applied += len(batch)
    vd.runtime.version += 1
    children = catalog.children_of(vd.name)
    if not children:
        return
    feats = vd.facade.margins_of(batch.ids, rows=batch.features)
    for child in children:
        _offer(catalog, child,
               Batch(list(batch.ids), list(batch.labels), feats), now)


# ---------------------------------------------------------------------------
# refresh (gate held exclusively by the caller)
# ---------------------------------------------------------------------------

def refresh_view(catalog, vd, now: Optional[float] = None,
                 _seen: Optional[set] = None) -> List[str]:
    """Bring `vd` up to date: drain ancestors first (topological order),
    consume the inbox batch-by-batch, re-pull derived features if the
    parent moved. Returns the names refreshed, ancestors first. Suspended
    views are left frozen (RESUME is their only way forward)."""
    now = catalog.clock() if now is None else now
    if _seen is None:
        _seen = set()
    out: List[str] = []
    if vd.name in _seen:
        return out
    _seen.add(vd.name)
    for parent in catalog.parents_of(vd.name):
        out.extend(refresh_view(catalog, parent, now, _seen))
    if vd.runtime.suspended:
        return out
    t0 = clock()
    modeled = modeled_catchup_cost(catalog, vd)
    inbox, vd.runtime.inbox = vd.runtime.inbox, []
    for batch in inbox:
        _consume_batch(catalog, vd, batch, now)
    if vd.source is not None:
        parent = catalog.view(vd.source)
        if parent.runtime.version != vd.runtime.upstream_version_seen:
            feats = parent.facade.margins_of(np.arange(parent.facade.n))
            vd.facade.refresh_features(feats)
            vd.runtime.upstream_version_seen = parent.runtime.version
            vd.runtime.version += 1
    if not upstream_blocked(catalog, vd):
        vd.runtime.stale_since = None
    vd.runtime.last_refresh_at = now
    vd.runtime.refreshes += 1
    # measured wall clock recorded ALONGSIDE the modeled charge — the
    # scheduler never reads it back (SHOW SCHEDULE / SHOW COST do)
    vd.runtime.cost.record_step(0, clock() - t0, modeled)
    out.append(vd.name)
    return out


def refresh_all(catalog, now: Optional[float] = None,
                only: Optional[str] = None) -> List[str]:
    """The refresh barrier: every view (or `only` + its ancestors) brought
    up to date in topological order. The wire `refresh` op and `ALTER
    VIEW ... REFRESH` land here."""
    now = catalog.clock() if now is None else now
    if only is not None:
        return refresh_view(catalog, catalog.view(only), now)
    seen: set = set()
    out: List[str] = []
    for vd in catalog.topo_order():
        out.extend(refresh_view(catalog, vd, now, seen))
    return out


def suspend_view(catalog, vd) -> None:
    """Freeze the view: reads keep serving the current labels; committed
    updates queue in the inbox (and in upstream emissions)."""
    vd.runtime.suspended = True


def resume_view(catalog, vd, now: Optional[float] = None) -> List[str]:
    """Unfreeze and catch up EXACTLY once: the queued batches replay with
    their original commit boundaries, so the round-trip is bit-identical
    to never having suspended."""
    vd.runtime.suspended = False
    return refresh_view(catalog, vd, now)


# ---------------------------------------------------------------------------
# cost + priority (what the daemon schedules on; SHOW SCHEDULE renders it)
# ---------------------------------------------------------------------------

def modeled_catchup_cost(catalog, vd) -> float:
    """SKIING-modeled cost of refreshing `vd` now, in touched-tuple units:
    queued training rows + the prospective band a maintenance round
    relabels + a full feature pull if the parent moved. Modeled only —
    measured wall clock is recorded alongside, never consulted."""
    cost = float(vd.runtime.inbox_rows())
    band, _, _ = vd.facade.band_info(0)
    cost += float(band)
    if vd.source is not None:
        parent = catalog.view(vd.source)
        if parent.runtime.version != vd.runtime.upstream_version_seen:
            cost += float(vd.facade.n)
    return cost


def priority(catalog, vd, now: float) -> Optional[float]:
    """(staleness / lag) damped by normalized modeled catch-up cost —
    None for views the scheduler doesn't manage."""
    lag = catalog.effective_lag(vd.name)
    if lag is None:
        return None
    urgency = vd.runtime.staleness(now) / lag
    cost_norm = modeled_catchup_cost(catalog, vd) / max(1, vd.facade.n)
    return urgency / (1.0 + cost_norm)


def schedule_snapshot(catalog, now: Optional[float] = None) -> List[dict]:
    """One row per view: the freshness ledger `SHOW SCHEDULE` renders and
    the metrics registry collects."""
    now = catalog.clock() if now is None else now
    rows = []
    for vd in catalog.topo_order():
        rt = vd.runtime
        lag = catalog.effective_lag(vd.name)
        state = ("suspended" if rt.suspended
                 else "scheduled" if lag is not None else "immediate")
        pr = priority(catalog, vd, now)
        rows.append({
            "view": vd.name,
            "on": vd.source if vd.source is not None else vd.table,
            "state": state,
            "target_lag": vd.options.target_lag,
            "effective_lag": lag,
            "staleness_s": rt.staleness(now),
            "inbox_batches": len(rt.inbox),
            "inbox_rows": rt.inbox_rows(),
            "modeled_cost": modeled_catchup_cost(catalog, vd),
            "priority": pr,
            "refreshes": rt.refreshes,
            "rows_applied": rt.rows_applied,
            "last_refresh_age_s": (None if rt.last_refresh_at is None
                                   else max(0.0, now - rt.last_refresh_at)),
        })
    return rows
