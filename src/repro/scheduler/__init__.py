"""Freshness scheduler: maintain classification views to a `target_lag`.

The paper's lazy/hybrid policies (§3.4–3.5) decouple update arrival from
relabeling *within* one view; this package generalizes that across a
catalog of views in the Snowflake-Dynamic-Tables style: each view
declares a freshness target (`WITH (target_lag = '5 s' | 'downstream')`),
commits queue per-view batches instead of training synchronously, and a
background daemon decides when to pay SKIING-modeled catch-up cost —
refreshing DAGs of views-over-views in the catalog's topological order.

  state    per-view freshness ledger (inbox, stamps, SUSPEND flag)
  refresh  delivery + refresh mechanics (the ONLY module that mutates
           freshness state — rule FRS001 in `repro.analysis` pins this)
  daemon   the `FreshnessScheduler` thread and its priority policy
"""
from repro.scheduler.daemon import FreshnessScheduler
from repro.scheduler.refresh import refresh_all, schedule_snapshot
from repro.scheduler.state import Batch, ViewRuntime

__all__ = ["FreshnessScheduler", "refresh_all", "schedule_snapshot",
           "Batch", "ViewRuntime"]
