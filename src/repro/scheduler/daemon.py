"""The freshness scheduler daemon.

One background thread per server. Each loop iteration ("tick") scans the
catalog for views whose staleness is approaching their effective lag,
picks the most urgent one — (staleness / lag) damped by SKIING-modeled
catch-up cost — and refreshes it (plus any stale ancestors, in
topological order) inside ONE exclusive slice of the executor's epoch
gate. Short slices keep the p99 of concurrent point reads bounded: the
gate is held per refresh, not per scan.

The daemon is deliberately dumb about time: it reads `self.clock`
(defaults to the catalog's clock) and exposes a synchronous `tick(now)`
so tests drive it with a modeled clock and assert the schedule
deterministically — same stream + same lags ⇒ same `schedule_log`.
"""
from __future__ import annotations

import logging
import threading
from typing import List, Optional, Tuple

from repro.scheduler import refresh as fr

logger = logging.getLogger(__name__)

#: refresh when staleness has consumed this fraction of the target lag —
#: scheduling AT the deadline would mean every refresh lands late by one
#: slice; half-lag headroom keeps measured staleness ≤ lag.
HEADROOM = 0.5


class FreshnessScheduler:
    """Background refresher maintaining views to their `target_lag`."""

    def __init__(self, executor, *, interval: float = 0.05,
                 headroom: float = HEADROOM, clock=None):
        self.executor = executor
        self.catalog = executor.catalog
        self.clock = clock if clock is not None else self.catalog.clock
        self.interval = float(interval)
        self.headroom = float(headroom)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        #: (tick index, names refreshed) — the determinism tests' witness
        self.schedule_log: List[Tuple[int, Tuple[str, ...]]] = []
        self.ticks = 0
        m = executor.metrics
        self._m_ticks = m.counter("scheduler.ticks")
        self._m_slices = m.counter("scheduler.slices")
        self._m_refreshes = m.counter("scheduler.refreshes")
        self._m_rows = m.counter("scheduler.rows_applied")

    # -- scheduling policy ------------------------------------------------

    def due(self, now: float):
        """Views worth refreshing now: scheduler-managed, not suspended,
        not starved by a suspended ancestor, staleness past the headroom
        fraction of their effective lag. Catalog topological order —
        stable, so ties break deterministically."""
        out = []
        for vd in self.catalog.topo_order():
            rt = vd.runtime
            if rt.suspended:
                continue
            lag = self.catalog.effective_lag(vd.name)
            if lag is None:
                continue
            if rt.stale_since is None:
                continue
            if fr.upstream_blocked(self.catalog, vd):
                continue
            if rt.staleness(now) >= self.headroom * lag:
                out.append(vd)
        return out

    def tick(self, now: Optional[float] = None) -> List[str]:
        """One scheduling decision: pick the highest-priority due view,
        refresh it (ancestors first) under an exclusive gate slice.
        Synchronous and clock-injectable — the unit tests call this
        directly; the daemon thread calls it in a loop."""
        now = self.clock() if now is None else now
        self.ticks += 1
        self._m_ticks.inc()
        due = self.due(now)
        if not due:
            return []
        vd = max(due, key=lambda v: fr.priority(self.catalog, v, now))
        with self.executor.gate.write():
            rows_before = vd.runtime.rows_applied
            names = fr.refresh_view(self.catalog, vd, now)
        self._m_slices.inc()
        self._m_refreshes.inc(len(names))
        self._m_rows.inc(vd.runtime.rows_applied - rows_before)
        self.schedule_log.append((self.ticks, tuple(names)))
        return names

    # -- daemon lifecycle -------------------------------------------------

    def start(self) -> "FreshnessScheduler":
        if self._thread is not None:
            return self
        self._stop.clear()
        self._thread = threading.Thread(target=self._run,
                                        name="freshness-scheduler",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    def _run(self) -> None:
        while not self._stop.is_set():
            try:
                refreshed = self.tick()
            except Exception:          # pragma: no cover - defensive
                logger.exception("freshness scheduler tick failed")
                refreshed = []
            if not refreshed:
                # nothing due: sleep one interval (wakes early on stop)
                self._stop.wait(self.interval)
